"""Paged prefill path: link → selective prefill straight into the KV pool.

Parity against the dense selective-prefill policies (Pallas kernel in
interpret mode, GQA/MQA/windowed sweep), bucketed pad-masking correctness,
the compile-count guard (same-bucket prompt lengths must NOT retrace), and
the engine-level guarantee that the mpic path never materializes or splices
a dense blended cache.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache import KVLibrary, PagedConfig, PagedKVPool
from repro.configs import get_smoke_config
from repro.configs.base import ModelConfig
from repro.core import (POLICIES, Prompt, media_segment,
                        precompute_media_kv, text_segment)
from repro.core.paged_prefill import PagedPrefiller, bucket
from repro.data import image_embeds
from repro.models import build_model
from repro.serving import EngineConfig, MPICEngine, Request

PAGE = 8


def _tiny_cfg(hq, hkv, window=0):
    return ModelConfig(name=f"tiny-{hq}-{hkv}", arch_type="dense",
                       num_layers=2, d_model=64, num_heads=hq,
                       num_kv_heads=hkv, head_dim=16, d_ff=128,
                       vocab_size=128, sliding_window=window,
                       param_dtype="float32", compute_dtype="float32")


def _setup(cfg, media_len=16):
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    emb = image_embeds("IMG", media_len, cfg.d_model)
    lib = KVLibrary()
    k, v = precompute_media_kv(model, params, jnp.asarray(emb))
    lib.put("u", "IMG", k, v)
    prompt = Prompt([
        text_segment(rng.integers(1, cfg.vocab_size, 5)),
        media_segment("IMG", emb),
        text_segment(rng.integers(1, cfg.vocab_size, 4)),
    ], user_id="u")
    return model, params, lib, prompt


def _pool_prefiller(model, n_tokens, *, backend="pallas", bucket_min=16,
                    dtype="float32"):
    pool = PagedKVPool(PagedConfig(
        num_pages=2 + -(-n_tokens // PAGE), page_size=PAGE,
        num_layers=model.cfg.num_layers, num_kv_heads=model.cfg.num_kv_heads,
        head_dim=model.cfg.head_dim, dtype=dtype))
    scratch = int(pool.alloc("__scratch__", 1)[0])
    pages = pool.alloc("r", n_tokens)
    pf = PagedPrefiller(model, pool, scratch, backend=backend,
                        interpret=True, bucket_min=bucket_min)
    return pool, pf, pages


# fp32 pool matches the dense policies exactly; the int8 pool quantizes
# on write (link + prefill scatter) and dequantizes in-kernel, so the
# first-token logits carry bounded KV-quantization error, and the gathered
# KV is within a few per-page quantization steps (the running-amax write
# protocol may requantize link-time rows when the prefill raises a page's
# scale, compounding the single-step amax/254 bound)
POOL_TOL = {"float32": dict(atol=1e-4, rtol=1e-4),
            "int8": dict(atol=5e-2, rtol=0)}


@pytest.mark.parametrize("pool_dtype", ["float32", "int8"])
@pytest.mark.parametrize("hq,hkv,window", [
    (4, 4, 0),      # MHA, full causal
    (4, 2, 0),      # GQA 2:1
    (8, 1, 0),      # MQA
    (4, 2, 6),      # GQA + sliding window that binds across the prompt
])
def test_paged_prefill_matches_dense_policy(hq, hkv, window, pool_dtype):
    """mpic through the paged step (Pallas, interpret=True) == dense mpic:
    same first-token logits AND matching pool KV vs the dense blended
    cache over every real slot (exact for fp32, POOL_TOL for int8)."""
    cfg = _tiny_cfg(hq, hkv, window)
    model, params, lib, prompt = _setup(cfg)
    total = prompt.total_len

    dense = POLICIES["mpic"](model, params, prompt, lib, k=4)
    pool, pf, pages = _pool_prefiller(model, total + 1, dtype=pool_dtype)
    paged = POLICIES["mpic"](model, params, prompt, lib, k=4,
                             paged=pf.bind(pages))
    assert paged.cache is None and paged.stats["paged_prefill"] is True
    assert paged.stats["n_recomputed"] == dense.stats["n_recomputed"]
    np.testing.assert_allclose(paged.first_logits, dense.first_logits,
                               **POOL_TOL[pool_dtype])
    k_pool, v_pool = pool.gather(pages, total)
    k_want = np.asarray(dense.cache["k"][:, 0, :total])
    v_want = np.asarray(dense.cache["v"][:, 0, :total])
    if pool.quantized:
        # bound the error in units of each page's OWN quantization step
        # (the fp32 scale the kernel dequantizes with): link-time rows get
        # requantized when the prefill scatter raises a page's running
        # amax, so a row can be a few steps off — but never many
        page_of = np.asarray(pages)[np.arange(total) // PAGE]
        for got, want, sc in ((k_pool, k_want, pool.k_scale),
                              (v_pool, v_want, pool.v_scale)):
            step = np.asarray(sc)[:, page_of][..., None]   # (L, S, H, 1)
            err = np.abs(np.asarray(got) - want)
            worst = float((err / np.maximum(step, 1e-9)).max())
            assert worst <= 5.0, f"gather off by {worst:.2f} quant steps"
    else:
        np.testing.assert_allclose(np.asarray(k_pool), k_want,
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(v_pool), v_want,
                                   atol=1e-5, rtol=1e-5)


def test_cacheblend_paged_matches_dense_policy(monkeypatch):
    """Same deviation-driven selection through both paths ⇒ same logits.

    The probe itself only differs by float noise between the dense cache
    and the pool gather (rope_relink fused into the link jit), but that
    noise can flip a near-tied argpartition pick — so pin the selection and
    compare the *machinery* exactly."""
    cfg = _tiny_cfg(4, 2)
    model, params, lib, prompt = _setup(cfg)

    def fixed_selection(prompt_, dev, r):
        sel = np.zeros((prompt_.total_len,), bool)
        sel[~prompt_.media_mask()] = True          # all text
        media_idx = np.nonzero(prompt_.media_mask())[0]
        sel[media_idx[::3]] = True                 # every 3rd media token
        assert dev.shape == (prompt_.total_len,)
        return sel

    from repro.core import policies as pol_mod
    monkeypatch.setattr(pol_mod.sel_mod, "cacheblend_selection",
                        fixed_selection)
    dense = POLICIES["cacheblend"](model, params, prompt, lib, r=0.25)
    pool, pf, pages = _pool_prefiller(model, prompt.total_len + 1)
    paged = POLICIES["cacheblend"](model, params, prompt, lib, r=0.25,
                                   paged=pf.bind(pages))
    assert paged.cache is None and paged.stats["paged_prefill"] is True
    assert paged.stats["n_recomputed"] == dense.stats["n_recomputed"]
    np.testing.assert_allclose(paged.first_logits, dense.first_logits,
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("pool_dtype", ["float32", "int8"])
def test_bucket_padding_is_masked(pool_dtype):
    """The same prompt through a tight bucket (no padding) and a huge one
    (mostly padding rows + scratch-page writes) gives identical logits and
    identical pool KV — pad rows are fully absorbed.  On the int8 pool the
    pad rows must also leave the REAL pages' scales untouched (they park
    their amax on the scratch page), so the dequantized gathers stay
    bit-identical across buckets."""
    cfg = _tiny_cfg(4, 2)
    model, params, lib, prompt = _setup(cfg)
    total = prompt.total_len
    outs = []
    for bucket_min in (8, 128):
        pool, pf, pages = _pool_prefiller(model, total + 1,
                                          bucket_min=bucket_min,
                                          dtype=pool_dtype)
        res = POLICIES["mpic"](model, params, prompt, lib, k=4,
                               paged=pf.bind(pages))
        outs.append((res.first_logits, *map(np.asarray,
                                            pool.gather(pages, total))))
    np.testing.assert_allclose(outs[0][0], outs[1][0], atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(outs[0][1], outs[1][1], atol=1e-6, rtol=1e-6)
    np.testing.assert_allclose(outs[0][2], outs[1][2], atol=1e-6, rtol=1e-6)


def test_bucket_helper():
    assert [bucket(n, 8) for n in (1, 8, 9, 16, 17, 33)] == \
        [8, 8, 16, 16, 32, 64]


def _text_req(n, seed=0, **kw):
    r = np.random.default_rng(seed)
    return Request(prompt=Prompt([text_segment(r.integers(1, 100, n))],
                                 user_id="u"),
                   max_new_tokens=2, policy="mpic", policy_kwargs={"k": 4},
                   **kw)


def test_same_bucket_prompt_lengths_single_trace():
    """Two different prompt lengths inside one (selection, page) bucket pair
    must reuse the first compile; a length outside the bucket retraces."""
    cfg = _tiny_cfg(4, 2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = MPICEngine(model, params,
                     EngineConfig(max_seq_len=128, decode_slots=2, paged=True,
                                  page_size=PAGE, prefill_bucket_min=16))
    # selection buckets: 20 → 32, 24 → 32 (warm);  40 → 64 (one new trace)
    for n, seed in ((20, 0), (24, 1)):
        eng.submit(_text_req(n, seed))
    eng.run()
    assert eng.prefill_trace_count == 1, \
        "same-bucket prompt lengths must not retrace the prefill jit"
    eng.submit(_text_req(40, 2))
    eng.run()
    assert eng.prefill_trace_count == 2


def test_engine_mpic_path_never_splices_dense_cache():
    """On the paged engine, mpic admission goes link → selective prefill →
    first token entirely inside the pool: no dense blended cache reaches
    ``_splice_paged``.  A policy with no paged route (full_recompute) still
    splices — the counter proves the hook is live."""
    cfg = _tiny_cfg(4, 2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = MPICEngine(model, params,
                     EngineConfig(max_seq_len=128, decode_slots=2, paged=True,
                                  page_size=PAGE))
    calls = []
    orig = eng._splice_paged
    eng._splice_paged = lambda *a, **kw: (calls.append(a), orig(*a, **kw))
    eng.upload("u", "IMG", image_embeds("IMG", 16, cfg.d_model))
    r = np.random.default_rng(0)
    prompt = Prompt([
        text_segment(r.integers(1, 100, 6)),
        media_segment("IMG", image_embeds("IMG", 16, cfg.d_model)),
    ], user_id="u")
    req = eng.submit(Request(prompt=prompt, max_new_tokens=3, policy="mpic",
                             policy_kwargs={"k": 4}))
    eng.run()
    assert req.done and not calls
    assert req.prefill_stats.get("paged_prefill") is True
    eng.submit(Request(prompt=Prompt([text_segment(
        np.random.default_rng(4).integers(1, 100, 10))], user_id="u"),
        max_new_tokens=2, policy="full_recompute"))
    eng.run()
    assert calls, "non-mpic policies keep the dense splice fallback"


def test_engine_outputs_identical_with_and_without_paged_prefill():
    """The paged prefill is a pure perf change: greedy continuations match
    the dense-prefill-then-splice path exactly (fp32 smoke llava)."""
    cfg = dataclasses.replace(get_smoke_config("llava-1.6-7b"),
                              param_dtype="float32", compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def drive(paged_prefill):
        eng = MPICEngine(model, params,
                         EngineConfig(max_seq_len=128, decode_slots=2,
                                      paged=True, page_size=PAGE,
                                      paged_prefill=paged_prefill))
        eng.upload("u1", "A", image_embeds("A", 16, cfg.d_model))
        reqs = []
        for i in range(3):
            r = np.random.default_rng(i)
            prompt = Prompt([
                text_segment(r.integers(8, 200, 5 + i)),
                media_segment("A", image_embeds("A", 16, cfg.d_model)),
                text_segment(r.integers(8, 200, 4)),
            ], user_id="u1")
            reqs.append(eng.submit(Request(prompt=prompt, max_new_tokens=5,
                                           policy="mpic",
                                           policy_kwargs={"k": 4})))
        eng.run()
        return eng, reqs

    eng_new, new = drive(True)
    eng_old, old = drive(False)
    assert eng_new._prefiller is not None and eng_old._prefiller is None
    for a, b in zip(new, old):
        assert a.output_tokens == b.output_tokens
    # pages fully recycled on completion, same as the splice path
    assert eng_new.pool.free_pages == eng_new.pool.cfg.num_pages - 1


def test_cacheblend_probe_ignores_stale_pool_bytes():
    """The deviation probe reads the pool BEFORE the prefill, so selected
    slots (text + missed media) must be blanked — a previous tenant's stale
    K in those pages must not steer cacheblend's selection (regression:
    the probe used to read them raw, breaking warm-pool determinism)."""
    cfg = _tiny_cfg(4, 2)
    model, params, lib, prompt = _setup(cfg)
    total = prompt.total_len

    def run(pollute):
        pool, pf, pages = _pool_prefiller(model, total + 1)
        if pollute:
            rng = np.random.default_rng(9)
            pool.k = pool.k + jnp.asarray(
                rng.standard_normal(pool.k.shape).astype(np.float32)) * 5.0
            pool.v = pool.v + jnp.asarray(
                rng.standard_normal(pool.v.shape).astype(np.float32)) * 5.0
        return POLICIES["cacheblend"](model, params, prompt, lib, r=0.25,
                                      paged=pf.bind(pages))

    clean, dirty = run(False), run(True)
    assert clean.stats["n_recomputed"] == dirty.stats["n_recomputed"]
    np.testing.assert_allclose(clean.first_logits, dirty.first_logits,
                               atol=1e-4, rtol=1e-4)


def test_missing_media_recomputed_on_paged_path():
    """A library miss forces the whole segment into the selection — the
    paged route must produce the full-recompute result, not stale pages."""
    cfg = _tiny_cfg(4, 2)
    model, params, _lib, prompt = _setup(cfg)
    empty = KVLibrary()
    oracle = POLICIES["full_recompute"](model, params, prompt)
    pool, pf, pages = _pool_prefiller(model, prompt.total_len + 1)
    res = POLICIES["mpic"](model, params, prompt, empty, k=4,
                           paged=pf.bind(pages))
    assert res.stats["misses"] == ["IMG"]
    assert res.stats["n_recomputed"] == prompt.total_len
    np.testing.assert_allclose(res.first_logits, oracle.first_logits,
                               atol=1e-4, rtol=1e-4)
