"""Cache-path consistency: prefill ≡ train forward, decode ≡ full forward,
for every family (this is the invariant all of MPIC rests on)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model

ATOL = 3e-2   # bf16 params, fp32 softmax


def _model(arch):
    cfg = get_smoke_config(arch)
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


@pytest.mark.parametrize("arch", ["yi-9b", "qwen2.5-14b", "stablelm-1.6b",
                                  "granite-moe-1b-a400m", "deepseek-moe-16b",
                                  "mamba2-130m", "hymba-1.5b"])
def test_prefill_matches_forward(arch):
    cfg, m, params = _model(arch)
    B, S = 2, 23
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    cache = m.make_cache(B, 64)
    lg, _ = m.prefill(params, toks, cache)
    full = m.forward(params, toks)
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(full, np.float32), atol=ATOL,
                               rtol=ATOL)


@pytest.mark.parametrize("arch", ["yi-9b", "granite-moe-1b-a400m",
                                  "mamba2-130m", "hymba-1.5b"])
def test_decode_matches_forward(arch):
    cfg, m, params = _model(arch)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    cache = m.make_cache(B, 64)
    lg, cache = m.prefill(params, toks, cache)
    cur = toks
    for step in range(3):
        nxt = jnp.argmax(lg[:, -1, :] if lg.ndim == 3 else lg,
                         -1)[:, None].astype(jnp.int32)
        pos = jnp.full((B, 1), S + step, jnp.int32)
        lg, cache = m.decode_step(params, nxt, pos, cache, pos)
        cur = jnp.concatenate([cur, nxt], axis=1)
        full = m.forward(params, cur)
        np.testing.assert_allclose(np.asarray(lg, np.float32),
                                   np.asarray(full[:, -1], np.float32),
                                   atol=ATOL, rtol=ATOL)


def test_whisper_prefill_decode():
    cfg, m, params = _model("whisper-small")
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    audio = jax.random.normal(jax.random.PRNGKey(2),
                              (B, cfg.encoder_seq, cfg.d_model)) * 0.02
    cache = m.make_cache(B, 64)
    lg, cache = m.prefill(params, toks, cache, audio_embeds=audio)
    full = m.forward(params, toks, audio_embeds=audio)
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(full, np.float32), atol=ATOL,
                               rtol=ATOL)
    nxt = jnp.argmax(lg[:, -1, :], -1)[:, None].astype(jnp.int32)
    pos = jnp.full((B, 1), S, jnp.int32)
    lg2, cache = m.decode_step(params, nxt, pos, cache, pos)
    full2 = m.forward(params, jnp.concatenate([toks, nxt], 1),
                      audio_embeds=audio)
    np.testing.assert_allclose(np.asarray(lg2, np.float32),
                               np.asarray(full2[:, -1], np.float32),
                               atol=ATOL, rtol=ATOL)


def test_sliding_window_masks_far_tokens():
    """With window w, tokens ≥ w behind the query must not contribute."""
    import dataclasses as dc
    cfg = dc.replace(get_smoke_config("yi-9b"), sliding_window=8)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 1, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    full = m.forward(params, toks)
    # perturbing a token far outside the window must not change last logits
    toks2 = toks.at[0, 2].set((toks[0, 2] + 1) % cfg.vocab_size)
    full2 = m.forward(params, toks2)
    np.testing.assert_allclose(np.asarray(full[:, -1], np.float32),
                               np.asarray(full2[:, -1], np.float32),
                               atol=1e-4, rtol=1e-4)


def test_vlm_media_injection_changes_output():
    cfg, m, params = _model("internvl2-76b")
    B, S = 1, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    mask = jnp.zeros((B, S), bool).at[:, 4:8].set(True)
    e1 = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model)) * 0.02
    e2 = e1 + 0.05
    l1 = m.forward(params, toks, media_embeds=e1, media_mask=mask)
    l2 = m.forward(params, toks, media_embeds=e2, media_mask=mask)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-4


def test_banded_attention_matches_full():
    """banded_attend (S×2w band) ≡ masked full attention, train + prefill."""
    import dataclasses as dc
    cfg = dc.replace(get_smoke_config("qwen2.5-14b"), sliding_window=8)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 32                     # S = 4w -> banded path active
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    banded = m.forward(params, toks)
    # explicit positions -> non-contiguous flag -> full attend path
    cache = m.make_cache(B, S + 1)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    full, _ = m.prefill(params, toks, cache, positions=pos, write_idx=pos)
    np.testing.assert_allclose(np.asarray(banded, np.float32),
                               np.asarray(full, np.float32),
                               atol=ATOL, rtol=ATOL)
