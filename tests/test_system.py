"""End-to-end system test: the paper's full pipeline at smoke scale —
upload → library → link → selective attention → decode — plus the headline
claims (quality ordering, single-step prefill, position independence)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.cache import KVLibrary
from repro.configs import get_smoke_config
from repro.core import POLICIES, Prompt, media_segment, text_segment
from repro.data import image_embeds, make_dialogues
from repro.models import build_model
from repro.serving import EngineConfig, MPICEngine, Request


def test_paper_pipeline_end_to_end(tmp_path):
    cfg = get_smoke_config("llava-1.6-7b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = MPICEngine(
        m, params, EngineConfig(max_seq_len=256, decode_slots=2),
        static_library=KVLibrary(spool_dir=str(tmp_path)))

    # workflow ①: uploads
    dialogues = make_dialogues(n=3, n_images=2, d_model=cfg.d_model,
                               media_len=16, style="mmdu", user_id="u1")
    seen = set()
    for d in dialogues:
        for mid in d.media_ids:
            if mid not in seen:
                eng.upload("u1", mid, image_embeds(mid, 16, cfg.d_model))
                seen.add(mid)

    # ②-⑥: submit with different opening words (the prefix-busting case)
    reqs = [eng.submit(Request(prompt=d.prompt, max_new_tokens=4,
                               policy="mpic", policy_kwargs={"k": 4}))
            for d in dialogues]
    done = eng.run()
    assert len(done) == 3
    for r in reqs:
        # both images' tails reused despite differing prefixes
        assert r.prefill_stats["n_reused"] == 2 * (16 - 4)
        assert r.prefill_stats["engine_steps"] == 1
        assert len(r.output_tokens) == 4


def test_quality_ordering_across_samples(tmp_path):
    """Aggregate over several dialogues: KL(mpic) < KL(full_reuse)."""
    cfg = get_smoke_config("llava-1.6-7b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    lib = KVLibrary(spool_dir=str(tmp_path))
    from repro.core import precompute_media_kv
    dialogues = make_dialogues(n=4, n_images=2, d_model=cfg.d_model,
                               media_len=12, style="sparkles", user_id="u1")
    for d in dialogues:
        for mid in d.media_ids:
            if lib.get("u1", mid) is None:
                k, v = precompute_media_kv(
                    m, params, jnp.asarray(image_embeds(mid, 12, cfg.d_model)))
                lib.put("u1", mid, k, v)

    def kl(p_logits, q_logits):
        p = jax.nn.softmax(jnp.asarray(p_logits))
        q = jax.nn.log_softmax(jnp.asarray(q_logits))
        return float(jnp.sum(p * (jnp.log(p + 1e-20) - q)))

    kls = {"mpic": [], "full_reuse": []}
    for d in dialogues:
        oracle = POLICIES["full_recompute"](m, params, d.prompt)
        for name, kw in (("mpic", {"k": 4}), ("full_reuse", {})):
            r = POLICIES[name](m, params, d.prompt, lib, **kw)
            kls[name].append(kl(oracle.first_logits, r.first_logits))
    assert np.mean(kls["mpic"]) < np.mean(kls["full_reuse"])
