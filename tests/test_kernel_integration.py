"""Integration: the Pallas selective-attention kernel computes the SAME
attention as the model's jnp path on a REAL MPIC linked cache (dummy
slots, relinked positions, scattered recompute) — proving the kernel is a
drop-in for the serving hot spot, not just a synthetic-shape toy."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.cache import KVLibrary
from repro.configs import get_smoke_config
from repro.core import (
    Prompt,
    link_prompt,
    media_segment,
    mpic_selection,
    precompute_media_kv,
    text_segment,
)
from repro.kernels import selective_attention
from repro.models import build_model
from repro.models.layers import attend, attention_qkv, rmsnorm


def test_kernel_matches_model_on_linked_cache(tmp_path):
    cfg = get_smoke_config("llava-1.6-7b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    emb = (rng.standard_normal((24, cfg.d_model)) * 0.02).astype(np.float32)
    lib = KVLibrary(spool_dir=str(tmp_path))
    k, v = precompute_media_kv(m, params, jnp.asarray(emb))
    lib.put("u", "IMG", k, v)

    prompt = Prompt([
        text_segment(rng.integers(8, 200, 9)),
        media_segment("IMG", emb),
        text_segment(rng.integers(8, 200, 7)),
    ], user_id="u")
    link = link_prompt(m, prompt, lib, mpic_selection(prompt, k=4))

    # layer-0 selected-token Q,K,V exactly as selective_prefill computes them
    sel_pos = jnp.asarray(link.sel_idx[None])
    x = m.embed(params, jnp.asarray(link.sel_tokens[None]),
                jnp.asarray(link.sel_media_embeds[None]),
                jnp.asarray(link.sel_media_mask[None]), sel_pos)
    lp0 = jax.tree_util.tree_map(lambda a: a[0], params["layers"])
    h = rmsnorm(lp0["attn_norm"], x, cfg.rms_norm_eps)
    q, k_new, v_new = attention_qkv(lp0["attn"], cfg, h, sel_pos)

    # blend: scatter recomputed K/V into the linked layer-0 cache
    k_full = link.cache["k"][0].at[:, link.sel_idx].set(
        k_new.astype(link.cache["k"].dtype))
    v_full = link.cache["v"][0].at[:, link.sel_idx].set(
        v_new.astype(link.cache["v"].dtype))
    kv_pos = link.cache["pos"].at[:, link.sel_idx].set(sel_pos)

    ref = attend(q, k_full, v_full, sel_pos, kv_pos)
    out = selective_attention(
        q.astype(jnp.float32), k_full.astype(jnp.float32),
        v_full.astype(jnp.float32), sel_pos, kv_pos,
        block_q=8, block_k=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-2, rtol=2e-2)
