"""Session KV store: freeze / thaw / fork of live decode state
(serving/sessions.py + the copy-on-write page pool underneath).

The contract under test is the tentpole invariant set:

  * **Resume parity** — ``frozen.output_tokens[:-1] + thawed.output_tokens``
    equals an uninterrupted session, bit-exactly, on the fp AND int8
    pools (the int8 snapshot rides raw page bytes + scale rows, no
    requant round trip).
  * **Fork is free until divergence** — N children share every parent
    page (zero copies, zero new pages beyond the parent footprint) and
    the first divergent write costs exactly N−1 page copies.
  * **Refcount soundness** — random freeze/thaw/fork/free/write
    sequences never leak a page, double-free one, or write into a page
    while it is shared (property test over the pool).
  * **Lifecycle plumbing** — FROZEN state, idle-sweep spooling, the
    ``sessions`` counter block in ``KVLibrary.stats()`` and the cluster
    ``report()``, and resume-anywhere via the cluster's thaw routing.
"""
from collections import Counter

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                                   # pragma: no cover
    from tests._hypothesis_fallback import given, settings, strategies as st

from repro.cache import TIER_DISK
from repro.cache.paged import PagedConfig, PagedKVPool
from repro.configs import get_smoke_config
from repro.core import Prompt, text_segment
from repro.serving import (
    ClusterConfig,
    EngineConfig,
    MPICCluster,
    MPICEngine,
    Request,
)


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_smoke_config("llava-1.6-7b")
    from repro.models import build_model
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def _toks(seed, n=12):
    return np.random.default_rng(seed).integers(8, 200, n)


def _req(toks, *, max_new=8, freeze_after=None, user="u", seed=0):
    return Request(prompt=Prompt([text_segment(toks)], user_id=user),
                   max_new_tokens=max_new, policy="full_recompute",
                   seed=seed, freeze_after=freeze_after)


def _eng(m, params, lib=None, *, slots=2, dtype="", idle=0.0):
    return MPICEngine(m, params,
                      EngineConfig(max_seq_len=128, decode_slots=slots,
                                   pool_dtype=dtype, freeze_idle_s=idle),
                      static_library=lib)


# ---------------------------------------------------------------------------
# resume parity (the acceptance criterion, both pool dtypes)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pool_dtype", ["", "int8"])
def test_thaw_resumes_token_identical(model_and_params, pool_dtype):
    """Freeze mid-decode, thaw on a DIFFERENT engine sharing only the
    library: the composed output equals a never-frozen run bit-exactly
    (int8: the snapshot restores raw page bytes + per-page scales, so
    even the lossy pool resumes on its own exact state)."""
    cfg, m, params = model_and_params
    toks = _toks(3)

    e1 = _eng(m, params, dtype=pool_dtype)
    base = _req(toks)
    e1.submit(base)
    e1.run()

    e2 = _eng(m, params, dtype=pool_dtype)
    fz = _req(toks, freeze_after=4)
    e2.submit(fz)
    e2.run()
    assert fz.state.value == "frozen"
    assert fz in e2.frozen and fz.slot == -1
    assert e2.pool.owned_pages(fz.req_id) == 0     # frozen = zero pages
    handle = e2.sessions.handles[fz.session_id]
    assert handle.n_ctx == len(toks) + 3           # prompt + outputs[:-1]
    assert handle.next_token == fz.output_tokens[-1]

    e3 = _eng(m, params, e2.static_lib, dtype=pool_dtype)
    th = e3.thaw(handle)
    e3.run()
    assert fz.output_tokens[:-1] + th.output_tokens == base.output_tokens


def test_suffix_thaw_matches_cold_recompute(model_and_params):
    """Thawing with the next turn's suffix (adopt pages + prefill ONLY
    the suffix) produces the same greedy tokens as re-prefilling the
    whole history from scratch."""
    cfg, m, params = model_and_params
    toks = _toks(5)
    suffix = [int(t) for t in _toks(6, 5)]

    e1 = _eng(m, params)
    fz = _req(toks, freeze_after=4)
    e1.submit(fz)
    e1.run()
    h = e1.sessions.handles[fz.session_id]

    e2 = _eng(m, params, e1.static_lib)
    th = e2.thaw(h, suffix, max_new_tokens=4)
    assert th.prefill_stats["thawed"]
    assert th.prefill_stats["n_reused"] == h.n_ctx
    assert th.prefill_stats["n_recomputed"] == len(suffix) + 1
    e2.run()

    e3 = _eng(m, params)
    full = list(toks) + fz.output_tokens[:-1] + [h.next_token] + suffix
    cold = _req(np.asarray(full, np.int32), max_new=4)
    e3.submit(cold)
    e3.run()
    assert th.output_tokens == cold.output_tokens


# ---------------------------------------------------------------------------
# fork: copy-on-write sharing
# ---------------------------------------------------------------------------


def test_fork_allocates_nothing_until_divergence(model_and_params):
    """N forked children allocate ZERO new pages at fork time (every
    parent page is shared) and the first divergent write costs exactly
    N−1 page copies — the last owner writes in place."""
    cfg, m, params = model_and_params
    e1 = _eng(m, params)
    fz = _req(_toks(7), freeze_after=4)
    e1.submit(fz)
    e1.run()
    h = e1.sessions.handles[fz.session_id]

    e = _eng(m, params, e1.static_lib, slots=4)
    free0 = e.pool.free_pages
    kids = e.fork(h, 3, max_new_tokens=3)
    parent_pages = e.pool.pages_for(h.n_ctx + 1)
    assert e.pool.cow_copies == 0
    assert e.pool.free_pages == free0 - parent_pages
    assert e.pool.pages_shared == parent_pages * 3
    for k in kids:
        assert k.output_tokens == [h.next_token]

    e.run()
    assert e.pool.cow_copies == 2                  # n−1 divergence cost
    # identical seeds + greedy tail → children decode identical tokens,
    # each on its own (partially CoW-copied) page table
    assert kids[0].output_tokens == kids[1].output_tokens \
        == kids[2].output_tokens
    sess = e.static_lib.stats()["sessions"]
    assert sess["forks"] == 3 and sess["cow_copies"] == 2
    assert sess["pages_shared"] == parent_pages * 3


# ---------------------------------------------------------------------------
# lifecycle plumbing: errors, idle sweep, counters, cluster routing
# ---------------------------------------------------------------------------


def test_freeze_thaw_error_paths(model_and_params):
    cfg, m, params = model_and_params
    e = _eng(m, params)
    with pytest.raises(KeyError):
        e.freeze("no-such-req")
    fz = _req(_toks(9), freeze_after=3)
    e.submit(fz)
    e.run()
    h = e.sessions.handles[fz.session_id]
    # pool-geometry mismatch is refused up front, not corrupted into
    e8 = _eng(m, params, e.static_lib, dtype="int8")
    with pytest.raises(ValueError, match="pool"):
        e8.thaw(h)
    with pytest.raises(ValueError):
        e.fork(h, 0)
    # thawing an evicted/unknown snapshot is a LookupError
    e.static_lib.delete(h.user_id, h.media_id)
    e2 = _eng(m, params, e.static_lib)
    with pytest.raises(LookupError):
        e2.thaw(h)


def test_idle_sweep_spools_frozen_sessions(model_and_params):
    """With ``freeze_idle_s`` set, a frozen handle idle past the
    threshold is demoted to the disk tier by the engine's step sweep."""
    cfg, m, params = model_and_params
    e = _eng(m, params, idle=30.0)
    fz = _req(_toks(11))
    e.submit(fz)
    while len(fz.output_tokens) < 3:
        e.step()
    # manual freeze keeps the snapshot memory-resident (spool=False);
    # freeze_after-triggered freezes spool immediately instead
    h = e.freeze(fz.req_id)
    assert e.static_lib.peek_tier(h.user_id, h.media_id,
                                  salt=h.cache_salt) != TIER_DISK
    assert e.sessions.sweep_idle(30.0) == 0        # not idle long enough
    h.frozen_at -= 60.0
    e.step()                                       # sweep runs in step()
    assert e.static_lib.peek_tier(h.user_id, h.media_id,
                                  salt=h.cache_salt) == TIER_DISK
    assert e.sessions.stats()["spooled_handles"] == 1
    # spooled is still thawable (disk → pages)
    e2 = _eng(m, params, e.static_lib)
    th = e2.thaw(h)
    e2.run()
    assert th.output_tokens[0] == h.next_token


def test_session_counters_and_cluster_resume(model_and_params):
    """freeze/thaw/fork counters aggregate into ``stats()['sessions']``
    and the cluster ``report()``; a session frozen on one replica thaws
    on whichever replica has slot headroom (shared library)."""
    cfg, m, params = model_and_params
    cluster = MPICCluster(m, params,
                          EngineConfig(max_seq_len=128, decode_slots=2),
                          ClusterConfig(replicas=2))
    fz = _req(_toks(13), freeze_after=3)
    cluster.submit(fz)
    cluster.run()
    assert fz.state.value == "frozen"
    handles = cluster.session_handles()
    assert fz.session_id in handles
    h = handles[fz.session_id]

    th = cluster.thaw(h)
    cluster.run()
    assert th.replica in (0, 1)
    assert th.output_tokens[0] == h.next_token

    kids = cluster.fork(h, 2)
    cluster.run()
    assert len({k.replica for k in kids}) == 1     # one pool, one replica
    rep = cluster.report()
    assert rep["sessions"]["freezes"] == 1
    assert rep["sessions"]["thaws"] == 1
    assert rep["sessions"]["forks"] == 2
    assert rep["sessions"]["pages_shared"] > 0


# ---------------------------------------------------------------------------
# property test: pool refcount invariants under random op sequences
# ---------------------------------------------------------------------------


@st.composite
def _op_seqs(draw):
    n = draw(st.integers(min_value=5, max_value=30))
    return [(draw(st.sampled_from(
                ["alloc", "extend", "free", "fork", "write"])),
             draw(st.integers(min_value=0, max_value=7)),
             draw(st.integers(min_value=1, max_value=9)))
            for _ in range(n)]


@given(ops=_op_seqs())
@settings(max_examples=25, deadline=None)
def test_pool_refcount_invariants(ops):
    """Whatever interleaving of alloc/extend/free/fork/write happens, the
    pool never leaks a page, never double-frees one, and never lets a
    write land in a page that is still shared."""
    NP, PS = 12, 4
    pool = PagedKVPool(PagedConfig(num_pages=NP, page_size=PS,
                                   num_layers=1, num_kv_heads=1,
                                   head_dim=4))
    tokens = {}
    next_id = 0
    for op, a, b in ops:
        names = sorted(tokens)
        if op == "alloc":
            rid = f"r{next_id}"
            next_id += 1
            if pool.alloc(rid, b) is not None:
                tokens[rid] = b
        elif op == "extend" and names:
            rid = names[a % len(names)]
            if pool.extend(rid, b, tokens[rid]) is not None:
                tokens[rid] += b
        elif op == "free" and names:
            rid = names[a % len(names)]
            pool.free(rid)
            del tokens[rid]
            pool.free(rid)                         # double-free: no-op
        elif op == "fork" and names:
            rid = names[a % len(names)]
            kids = [f"r{next_id + i}" for i in range(1 + a % 2)]
            next_id += len(kids)
            pool.fork(rid, kids)
            for kid in kids:
                tokens[kid] = tokens[rid]
        elif op == "write" and names:
            rid = names[a % len(names)]
            pos = (b - 1) % max(tokens[rid], 1)
            pages = pool.make_exclusive(rid, pos)
            if pages is not None:                  # None = CoW budget miss
                # the write target must now be exclusively owned
                assert pool.page_ref(int(pages[pos // PS])) == 1

        # invariants, after every op -----------------------------------
        owned = [int(p) for r in tokens for p in pool._owned[r]]
        distinct = set(owned)
        free = set(pool._free)
        assert len(pool._free) == len(free)        # free stack: no dups
        assert not (distinct & free)               # never free AND owned
        assert len(distinct) + len(free) == NP     # no page leaked/lost
        for page, holders in Counter(owned).items():
            assert pool._refs.get(page) == holders  # refs == owner count
        for page in free:
            assert pool._refs.get(page, 0) == 0
