"""Mesh-sharded serving: tensor-parallel paged decode/prefill parity.

The numeric checks need >1 device, but the device count locks at backend
init and conftest must keep this process on 1 CPU device — so every
multi-device case runs ``tests/_sharded_worker.py`` in a SUBPROCESS with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``, and the dryrun
check runs ``repro.launch.dryrun --serving-selftest`` (which forces its
own 512 placeholder devices for the 16×16 production mesh).  In-process
tests cover the sharded code path itself on a trivial 1×1 mesh.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

TESTS = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(TESTS)
WORKER = os.path.join(TESTS, "_sharded_worker.py")


def _sub_env(**extra):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)          # children pick their own count
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    env.update(extra)
    return env


def _run(cmd, *, timeout=900):
    p = subprocess.run(cmd, capture_output=True, text=True, env=_sub_env(),
                       timeout=timeout, cwd=ROOT)
    assert p.returncode == 0, (
        f"{' '.join(cmd)} failed ({p.returncode})\n"
        f"--- stdout ---\n{p.stdout[-4000:]}\n"
        f"--- stderr ---\n{p.stderr[-4000:]}")
    return p.stdout


@pytest.mark.parametrize("case", ["kernel", "decode", "prefill", "mrag",
                                  "cacheblend", "dense", "nondiv", "int8"])
def test_sharded_parity_4dev(case):
    """4-device sharded serving numerically matches the 1-device path."""
    out = _run([sys.executable, WORKER, case])
    assert f"PARITY-OK {case}" in out


def test_dryrun_serving_selftest():
    """dryrun AOT-lowers the sharded serving step on the 16×16 mesh and
    asserts kv-heads stay partitioned on 'model' (no arrays)."""
    out = _run([sys.executable, "-m", "repro.launch.dryrun",
                "--serving-selftest"])
    assert "serving selftest OK" in out
    assert "pool kv-heads on 'model' in+out" in out


def test_dryrun_import_does_not_lock_devices():
    """Satellite regression: importing launch.dryrun must NOT set XLA_FLAGS
    (the seed module did, locking any importer to 512 fake devices)."""
    out = _run([sys.executable, "-c",
                "import repro.launch.dryrun, jax, os; "
                "assert 'xla_force_host_platform_device_count' not in "
                "os.environ.get('XLA_FLAGS', ''); "
                "print('DEV', len(jax.devices()))"])
    assert "DEV 1" in out


# ---------------------------------------------------------------------------
# in-process: the sharded code path on a trivial 1×1 mesh (runs under the
# normal 1-device suite; proves mesh plumbing adds no numeric drift and the
# divisibility guards behave)
# ---------------------------------------------------------------------------

def _tiny_cfg():
    from repro.configs.base import ModelConfig
    return ModelConfig(name="mesh1x1-vlm", arch_type="vlm", num_layers=2,
                       d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
                       d_ff=128, vocab_size=256, is_multimodal=True,
                       media_token_len=16, param_dtype="float32",
                       compute_dtype="float32")


def test_engine_mesh_1x1_matches_unsharded():
    from repro.core import Prompt, media_segment, text_segment
    from repro.data import image_embeds
    from repro.launch.mesh import make_serving_mesh
    from repro.models import build_model
    from repro.serving import EngineConfig, MPICEngine, Request

    cfg = _tiny_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    r = np.random.default_rng(0)

    def prompt():
        return Prompt([text_segment(r.integers(8, 200, 5)),
                       media_segment("A", image_embeds("A", 16,
                                                       cfg.d_model))],
                      user_id="u1")

    outs = []
    for mesh in (None, make_serving_mesh(data=1, model=1)):
        eng = MPICEngine(model, params,
                         EngineConfig(max_seq_len=128, decode_slots=2),
                         mesh=mesh)
        eng.upload("u1", "A", image_embeds("A", 16, cfg.d_model))
        r = np.random.default_rng(0)
        req = eng.submit(Request(prompt=prompt(), max_new_tokens=5,
                                 policy="mpic", policy_kwargs={"k": 4}))
        eng.run()
        outs.append(req.output_tokens)
        if mesh is not None:
            assert eng.sharding is not None
            assert eng.pool.sharding is not None
    assert outs[0] == outs[1]


def test_serving_sharding_divisibility_guard():
    """kv heads that do not divide the model axis fall back to replicated
    (never a shape error) — the guard mirrors pspec.shard."""
    from repro.launch.mesh import make_serving_mesh
    from repro.serving.sharding import ServingSharding

    mesh = make_serving_mesh(data=1, model=1)
    sh = ServingSharding(mesh, _tiny_cfg())
    # everything divides a 1-way axis; unknown logical names stay None.
    # The real non-dividing fallback (6 kv heads on a 4-way axis ->
    # replicated, token-identical) runs in the 4-device worker ('nondiv').
    assert sh.axis("kv_heads", 4) == "model"
    assert sh.axis("kv_heads", 3) == "model"   # 3 % 1 == 0 on 1-way axis
    assert sh.axis("nonexistent", 4) is None
    spec = sh.pool().spec
    assert spec[3] == "model" and spec[0] is None
    assert sh.batched(2, 2).spec[0] in ("data", ("data",))
    assert sh.batched(3, 2).spec[0] in ("data", ("data",))  # 3 % 1 == 0


def test_serve_cli_mesh_parse():
    from repro.launch.serve import parse_mesh
    assert parse_mesh("none") is None
    m = parse_mesh("1x1")
    assert m.axis_names == ("data", "model")
    assert parse_mesh("auto").devices.size == len(jax.devices())
