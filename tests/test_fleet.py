"""Multi-process fleet: wire blobs, heartbeat routing, and the live
supervise→kill→failover→drain loop (launch/fleet.py).

The unit half runs in-process (encode/decode round-trips, heartbeat-fed
affinity views).  The smoke half spawns a REAL 2-host fleet — separate
engine processes with their own spool dirs and peer block servers —
serves a wave, ``kill -9``s one host mid-wave, and requires the
supervisor to finish everything and drain cleanly.  It is the CI fleet
job; pytest-timeout (marker below + the global cap) guards against a
wedged fleet hanging the suite.
"""
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import Prompt, media_segment, text_segment
from repro.data import image_embeds
from repro.launch.fleet import (
    FleetSupervisor,
    decode_request,
    encode_request,
    encode_upload,
    unpack_blob,
)
from repro.serving.request import Request


def _prompt(cfg, seed, media, user_id="u1"):
    r = np.random.default_rng(seed)
    segs = [text_segment(r.integers(8, 200, 6))]
    for mid, emb in media:
        segs.append(media_segment(mid, emb))
        segs.append(text_segment(r.integers(8, 200, 5)))
    return Prompt(segs, user_id=user_id)


# ---------------------------------------------------------------------------
# wire blobs
# ---------------------------------------------------------------------------


def test_request_blob_roundtrip():
    cfg = get_smoke_config("llava-1.6-7b")
    media = [("m0", image_embeds("m0", 16, cfg.d_model))]
    req = Request(prompt=_prompt(cfg, 0, media), policy="mpic",
                  policy_kwargs={"k": 4}, max_new_tokens=5, seed=99,
                  deadline_s=12.5, priority=2)
    got = decode_request(encode_request(req))
    assert got.req_id == req.req_id          # identity survives the wire
    assert got.policy == "mpic" and got.policy_kwargs == {"k": 4}
    assert got.max_new_tokens == 5 and got.seed == 99
    assert got.deadline_s == 12.5 and got.priority == 2
    assert got.prompt.user_id == "u1"
    assert len(got.prompt.segments) == len(req.prompt.segments)
    for a, b in zip(got.prompt.segments, req.prompt.segments):
        assert a.kind == b.kind and a.length == b.length
        np.testing.assert_array_equal(np.asarray(a.tokens if a.kind == "text"
                                                 else a.embeds),
                                      np.asarray(b.tokens if b.kind == "text"
                                                 else b.embeds))


def test_upload_blob_roundtrip():
    emb = image_embeds("mx", 8, 32)
    header, arrays = unpack_blob(
        encode_upload("u9", "mx", emb, ttl=60.0, dynamic=True))
    assert header["user_id"] == "u9" and header["media_id"] == "mx"
    assert header["ttl"] == 60.0 and header["dynamic"] is True
    np.testing.assert_array_equal(arrays["embeds"], np.asarray(emb))


# ---------------------------------------------------------------------------
# heartbeat-fed affinity routing (no processes)
# ---------------------------------------------------------------------------


def test_heartbeat_view_routes_to_warm_host():
    from repro.cache.backends import scope_digest
    from repro.serving.router import AffinityRouter, heartbeat_view

    cfg = get_smoke_config("llava-1.6-7b")
    media = [("warmmed", image_embeds("warmmed", 8, cfg.d_model))]
    req = Request(prompt=_prompt(cfg, 1, media), policy="mpic")
    ident = scope_digest(("u1", "warmmed"))
    load = {"free_slots": 2, "queue_depth": 0,
            "free_pages": 8, "total_pages": 8}
    cold = {"load": load, "media": {}}
    warm = {"load": load, "media": {ident: "disk"}}

    views = [heartbeat_view(0, "127.0.0.1:1000", cold, req),
             heartbeat_view(1, "127.0.0.1:1001", warm, req)]
    assert views[1].warmth == {"disk": 1}
    decision = AffinityRouter().route(req, views)
    assert decision.replica == 1             # disk-warm beats cold
    assert decision.address == "127.0.0.1:1001"   # route-by-address


# ---------------------------------------------------------------------------
# the real thing: 2 engine processes + router, kill one, drain
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.timeout(560)
def test_fleet_smoke_kill_one_host_drain_completes(tmp_path):
    cfg = get_smoke_config("llava-1.6-7b")
    fleet = FleetSupervisor(2, base_dir=str(tmp_path), hbm_bytes=1,
                            host_bytes=1, max_seq_len=1024,
                            heartbeat_s=0.2, miss_threshold=3,
                            linger_s=30.0)
    try:
        fleet.start()
        media = {f"fsm{i}": image_embeds(f"fsm{i}", 16, cfg.d_model)
                 for i in range(6)}
        for mid, emb in media.items():
            fleet.upload("u1", mid, emb)
        pairs = sorted(media.items())
        for i in range(6):
            req = Request(
                prompt=_prompt(cfg, 10 + i,
                               [pairs[i % 6], pairs[(i + 1) % 6]]),
                policy="mpic", policy_kwargs={"k": 4},
                max_new_tokens=6, seed=50 + i)
            fleet.submit(req)
        fleet.kill_host(0)        # kill -9 with the whole wave in flight
        fleet.run_until_done(timeout_s=420)

        rep = fleet.report()
        assert rep["completed"] == 6 and rep["failed"] == 0, rep
        assert rep["deaths"] >= 1, "the murder was never detected"

        # the restarted host rejoined warm: its spool rehydrated
        fleet.wait_healthy([0], timeout_s=240)
        stats = (fleet._host(0).health or {}).get("rehydrate", {})
        assert stats.get("rehydrated", 0) > 0, stats

        # graceful drain: every host process exits on its own
        fleet.drain(timeout_s=120)
        for h in fleet.hosts:
            assert h.proc is None or h.proc.poll() is not None, \
                f"host {h.spec.host_id} still running after drain"
    finally:
        fleet.stop()


@pytest.mark.slow
@pytest.mark.timeout(560)
def test_fleet_freeze_on_a_kill_a_thaw_on_b(tmp_path):
    """Session resume-anywhere across a host death: a session frozen
    (and spooled) on host A survives ``kill -9`` of A — the restarted A
    rehydrates the snapshot from its spool dir, and a thaw on host B
    pulls it over the peer block protocol.  The resumed output must be
    token-identical to a session that was never interrupted."""
    import time

    r = np.random.default_rng(7)
    toks = r.integers(8, 200, 12)

    def mk(**kw):
        return Request(prompt=Prompt([text_segment(toks)], user_id="u1"),
                       max_new_tokens=8, policy="full_recompute", seed=5,
                       **kw)

    fleet = FleetSupervisor(2, base_dir=str(tmp_path), slots=2,
                            heartbeat_s=0.2, miss_threshold=3,
                            linger_s=30.0)
    try:
        fleet.start()
        # unkilled baseline on host B
        base = mk()
        fleet.submit(base, host=1)
        fleet.run_until_done(timeout_s=240)
        base_toks = fleet.results[base.req_id]["tokens"]

        # freeze_after on host A: the host freezes + spools mid-decode
        # and reports a terminal "frozen" row carrying the handle
        fz = mk(freeze_after=4)
        fleet.submit(fz, host=0)
        fleet.run_until_done(timeout_s=240)
        row = fleet.results[fz.req_id]
        assert row["state"] == "frozen", row
        handle = row["session"]
        assert handle and handle["session_id"].startswith("sess-")
        assert handle["cache_salt"] and handle["n_ctx"] == 15
        # the freeze counter aggregates while A is still alive (its
        # in-process counters die with it below; the snapshot does not)
        fleet.heartbeat()
        assert fleet.report().get("sessions", {}).get("freezes", 0) >= 1

        # kill -9 host A; the supervisor detects the death and respawns
        # it with the same spool dir — the snapshot rehydrates from disk
        fleet.kill_host(0)
        deadline = time.monotonic() + 240
        while fleet.deaths == 0 and time.monotonic() < deadline:
            fleet.pump()
            time.sleep(0.05)
        assert fleet.deaths == 1, "the murder was never detected"
        fleet.wait_healthy([0], timeout_s=240)
        stats = (fleet._host(0).health or {}).get("rehydrate", {})
        assert stats.get("rehydrated", 0) > 0, stats

        # resume on host B: it never held the snapshot — the thaw's
        # library get falls through to the network tier and pulls the
        # block from the restarted A
        rid = fleet.thaw(1, handle)
        fleet.run_until_done(timeout_s=240)
        th = fleet.results[rid]
        assert th["state"] == "done" and th["host"] == 1, th
        assert row["tokens"][:-1] + th["tokens"] == base_toks

        # fleet-wide session visibility + aggregated counters
        assert handle["session_id"] in fleet.session_handles()
        fleet.heartbeat()
        rep = fleet.report()
        assert rep["frozen"] == 1
        assert rep.get("sessions", {}).get("thaws", 0) >= 1
        fleet.drain(timeout_s=120)
    finally:
        fleet.stop()


@pytest.mark.timeout(300)
def test_fleet_single_host_serves_and_drains(tmp_path):
    """1-host fleet: the degenerate topology must still serve + drain
    (covers the supervisor without the failover machinery)."""
    cfg = get_smoke_config("llava-1.6-7b")
    fleet = FleetSupervisor(1, base_dir=str(tmp_path), max_seq_len=1024,
                            heartbeat_s=0.25, linger_s=30.0)
    try:
        fleet.start()
        emb = image_embeds("solo", 16, cfg.d_model)
        fleet.upload("u1", "solo", emb)
        req = Request(prompt=_prompt(cfg, 3, [("solo", emb)]),
                      policy="mpic", policy_kwargs={"k": 4},
                      max_new_tokens=4, seed=7)
        fleet.submit(req)
        fleet.run_until_done(timeout_s=240)
        row = fleet.results[req.req_id]
        assert row["state"] == "done" and len(row["tokens"]) == 4
        assert row["n_reused"] > 0       # the uploaded block was reused
        fleet.drain(timeout_s=120)
    finally:
        fleet.stop()
