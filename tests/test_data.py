"""Data pipeline: tokenizer, dialogue generators, train batches."""
import numpy as np

from repro.data import ByteTokenizer, make_dialogues, train_batches


def test_tokenizer_roundtrip():
    tok = ByteTokenizer()
    s = "MPIC: position-independent caching! ünïcødé"
    assert tok.decode(tok.encode(s)) == s


def test_dialogue_styles_differ():
    mm = make_dialogues(n=2, n_images=3, d_model=64, media_len=8,
                        style="mmdu", seed=1)
    sp = make_dialogues(n=2, n_images=3, d_model=64, media_len=8,
                        style="sparkles", seed=1)
    # mmdu: media segments contiguous (sentence-level); sparkles interleaved
    kinds_mm = [s.kind for s in mm[0].prompt.segments]
    kinds_sp = [s.kind for s in sp[0].prompt.segments]
    i_mm = [i for i, k in enumerate(kinds_mm) if k == "image"]
    assert i_mm == list(range(i_mm[0], i_mm[0] + 3))      # contiguous block
    i_sp = [i for i, k in enumerate(kinds_sp) if k == "image"]
    assert i_sp != list(range(i_sp[0], i_sp[0] + 3))      # woven with text


def test_dialogues_are_deterministic():
    a = make_dialogues(n=2, n_images=2, d_model=32, seed=7)
    b = make_dialogues(n=2, n_images=2, d_model=32, seed=7)
    np.testing.assert_array_equal(a[0].prompt.flat_tokens(),
                                  b[0].prompt.flat_tokens())


def test_train_batches_shapes():
    it = train_batches(batch=3, seq=32, vocab=512, d_model=16)
    b = next(it)
    assert b["tokens"].shape == (3, 32)
    assert b["labels"].shape == (3, 32)
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()
    assert b["media_embeds"].shape == (3, 32, 16)
