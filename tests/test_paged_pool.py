"""PagedKVPool bookkeeping: alloc/extend/free, exhaustion, double-free,
and the donated token scatter."""
import jax.numpy as jnp
import numpy as np

from repro.cache import PagedConfig, PagedKVPool


def _pool(num_pages=8, page_size=4, dtype="float32"):
    return PagedKVPool(PagedConfig(num_pages=num_pages, page_size=page_size,
                                   num_layers=2, num_kv_heads=2, head_dim=8,
                                   dtype=dtype))


def test_alloc_extend_free_roundtrip():
    pool = _pool()
    pt = pool.alloc("r1", 10)              # 3 pages of 4
    assert len(pt) == 3 and pool.free_pages == 5
    assert pool.capacity("r1") == 12

    pt = pool.extend("r1", 3, 10)          # 13 tokens -> 4 pages
    assert len(pt) == 4 and pool.free_pages == 4

    # extend that still fits the owned pages allocates nothing
    pt = pool.extend("r1", 2, 13)          # 15 tokens -> still 4 pages
    assert len(pt) == 4 and pool.free_pages == 4

    pool.free("r1")
    assert pool.free_pages == 8 and pool.capacity("r1") == 0


def test_exhaustion_returns_none_and_leaks_nothing():
    pool = _pool(num_pages=4, page_size=4)
    assert pool.alloc("a", 12) is not None          # 3 of 4 pages
    assert pool.alloc("b", 8) is None               # needs 2, only 1 free
    assert pool.free_pages == 1                     # failed alloc took nothing
    assert pool.extend("a", 8, 12) is None          # needs 2 more, 1 free
    assert pool.owned_pages("a") == 3               # failed extend unchanged
    pool.free("a")
    assert pool.free_pages == 4


def test_double_free_is_safe():
    pool = _pool()
    pool.alloc("r1", 10)
    pool.free("r1")
    pool.free("r1")                                 # second free: no-op
    pool.free("never-allocated")
    assert pool.free_pages == 8
    assert sorted(pool._free) == list(range(8))     # no duplicated pages


def test_pages_are_recycled():
    pool = _pool(num_pages=4, page_size=4)
    first = set(pool.alloc("a", 16).tolist())
    pool.free("a")
    second = set(pool.alloc("b", 16).tolist())
    assert first == second


def test_write_tokens_scatter_and_gather():
    """write_tokens at a non-zero slot0 crossing a page boundary."""
    pool = _pool(page_size=4)
    pt = pool.alloc("r1", 11)
    vals = np.arange(2 * 11 * 2 * 8, dtype=np.float32).reshape(2, 11, 2, 8)
    # write tokens 3..10 (crosses pages 0->1->2)
    pool.write_tokens(pt, 3, jnp.asarray(vals[:, 3:]),
                      jnp.asarray(2 * vals[:, 3:]))
    k, v = pool.gather(pt, 11)
    np.testing.assert_allclose(np.asarray(k)[:, 3:], vals[:, 3:])
    np.testing.assert_allclose(np.asarray(v)[:, 3:], 2 * vals[:, 3:])
    np.testing.assert_allclose(np.asarray(k)[:, :3], 0.0)  # untouched
