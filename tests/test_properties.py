"""Hypothesis property tests on system invariants: prompt/selection
algebra, linker accounting, roofline HLO parsing."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.segments import Prompt, media_segment, text_segment
from repro.core.select import (
    full_reuse_selection,
    mpic_selection,
    selection_indices,
)
from repro.roofline.analysis import _group_size, _wire_bytes, collective_bytes


# ---------------------------------------------------------------------------
# prompt / selection algebra
# ---------------------------------------------------------------------------

@st.composite
def prompts(draw):
    rng = np.random.default_rng(draw(st.integers(0, 2 ** 16)))
    n_seg = draw(st.integers(1, 6))
    segs = []
    for i in range(n_seg):
        if draw(st.booleans()):
            ln = draw(st.integers(1, 20))
            segs.append(text_segment(rng.integers(8, 200, ln)))
        else:
            ln = draw(st.integers(1, 24))
            segs.append(media_segment(
                f"m{i}", rng.standard_normal((ln, 8)).astype(np.float32)))
    return Prompt(segs)


@settings(max_examples=40, deadline=None)
@given(p=prompts(), k=st.integers(0, 32))
def test_selection_partition_invariant(p, k):
    """Selected ∪ reused == all tokens; reused ⊆ media; text ⊆ selected."""
    sel = mpic_selection(p, k)
    media = p.media_mask()
    assert sel.shape == (p.total_len,)
    assert (~sel <= media).all()          # unselected -> media
    assert (sel[~media]).all()            # all text selected
    # exactly min(k, len) per media segment
    n_sel_media = sum(min(k, seg.length) for _, seg in p.media_segments())
    assert (sel & media).sum() == n_sel_media


@settings(max_examples=30, deadline=None)
@given(p=prompts())
def test_offsets_partition_prompt(p):
    offs = p.offsets()
    assert offs[0] == 0
    for (o, s), nxt in zip(zip(offs, p.segments), offs[1:] + [p.total_len]):
        assert o + s.length == nxt


@settings(max_examples=30, deadline=None)
@given(p=prompts(), k1=st.integers(0, 8), k2=st.integers(9, 64))
def test_selection_monotone_in_k(p, k1, k2):
    s1, s2 = mpic_selection(p, k1), mpic_selection(p, k2)
    assert (s1 <= s2).all()               # larger k selects a superset
    assert (full_reuse_selection(p) <= s1).all()


@settings(max_examples=30, deadline=None)
@given(p=prompts(), k=st.integers(0, 16))
def test_selection_indices_sorted_unique(p, k):
    idx = selection_indices(mpic_selection(p, k))
    assert (np.diff(idx) > 0).all() if len(idx) > 1 else True


# ---------------------------------------------------------------------------
# roofline HLO parsing
# ---------------------------------------------------------------------------

HLO_SAMPLE = """
  %ag = f32[256,8]{1,0} all-gather(%x), channel_id=1, replica_groups=[16,16]<=[16,16]T(1,0), dimensions={0}, metadata={op_name="jit(fn)/while/body/dot_general"}
  %ar = bf16[2,4096,5120]{2,1,0} all-reduce(%y), channel_id=2, replica_groups=[16,16]<=[256], metadata={op_name="jit(fn)/dot_general"}
  %cp = f32[32,16]{1,0} collective-permute(%z), channel_id=3, source_target_pairs={{0,1},{1,0}}, metadata={op_name="jit(fn)/while/body/while/body/split"}
"""


def test_collective_parser_kinds_and_multipliers():
    stats = collective_bytes(HLO_SAMPLE, trip_counts=[24, 8])
    # all-gather: 256*8*4 bytes * 15/16 * L(24)
    ag = 256 * 8 * 4 * 15 / 16 * 24
    # all-reduce: 2*4096*5120*2 * 2*(15/16), no loop
    ar = 2 * 4096 * 5120 * 2 * 2 * 15 / 16
    # permute: 32*16*4 at depth 2 -> *24*8
    cp = 32 * 16 * 4 * 24 * 8
    assert stats.by_kind["all-gather"] == pytest.approx(ag)
    assert stats.by_kind["all-reduce"] == pytest.approx(ar)
    assert stats.by_kind["collective-permute"] == pytest.approx(cp)
    assert stats.op_count == 3
    assert stats.total_bytes == pytest.approx(ag + ar + cp)


def test_wire_bytes_model():
    assert _wire_bytes("all-gather", 160, 16) == pytest.approx(150)
    assert _wire_bytes("all-reduce", 160, 16) == pytest.approx(300)
    assert _wire_bytes("reduce-scatter", 10, 16) == pytest.approx(150)
    assert _wire_bytes("collective-permute", 99, 4) == 99.0


def test_group_size_parsing():
    assert _group_size("replica_groups=[16,16]<=[256]") == 16
    assert _group_size("replica_groups={{0,1,2,3}}") == 4
