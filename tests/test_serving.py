"""Serving engine: continuous batching, policy fallback, MRAG linking."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import Prompt, media_segment, text_segment
from repro.data import image_embeds
from repro.models import build_model
from repro.serving import EngineConfig, MPICEngine, Request


@pytest.fixture(scope="module")
def engine():
    cfg = get_smoke_config("llava-1.6-7b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = MPICEngine(m, params,
                     EngineConfig(max_seq_len=128, decode_slots=2))
    for mid in ("A", "B"):
        eng.upload("u1", mid, image_embeds(mid, 16, cfg.d_model))
    eng.upload("*", "RAG1", image_embeds("RAG1", 12, cfg.d_model),
               dynamic=True)
    return cfg, eng


def _prompt(cfg, seed):
    r = np.random.default_rng(seed)
    return Prompt([
        text_segment(r.integers(8, 200, 5)),
        media_segment("A", image_embeds("A", 16, cfg.d_model)),
        text_segment(r.integers(8, 200, 4)),
        media_segment("B", image_embeds("B", 16, cfg.d_model)),
    ], user_id="u1")


def test_continuous_batching(engine):
    cfg, eng = engine
    reqs = [eng.submit(Request(prompt=_prompt(cfg, i), max_new_tokens=4,
                               policy="mpic", policy_kwargs={"k": 4}))
            for i in range(4)]   # 4 requests > 2 slots
    done = eng.run()
    assert len([r for r in done if r in reqs]) == 4
    for r in reqs:
        assert len(r.output_tokens) == 4
        assert r.ttft > 0
        assert r.prefill_stats["n_reused"] == 2 * (16 - 4)


def test_mrag_dynamic_link(engine):
    cfg, eng = engine
    req = Request(prompt=_prompt(cfg, 99), max_new_tokens=3, policy="mpic",
                  policy_kwargs={"k": 4})
    req.retrieval_query = image_embeds("RAG1", 12, cfg.d_model).mean(0)
    eng.submit(req)
    eng.run()
    # retrieved entry linked position-independently, no prefill recompute
    assert "RAG1" in req.linked_media


def test_ssm_policy_fallback():
    cfg = get_smoke_config("mamba2-130m")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = MPICEngine(m, params, EngineConfig(max_seq_len=96, decode_slots=1))
    r = np.random.default_rng(0)
    req = Request(prompt=Prompt([text_segment(r.integers(8, 200, 20))],
                                user_id="u"),
                  max_new_tokens=3, policy="mpic")
    eng.submit(req)
    eng.run()
    # MPIC inapplicable to attention-free archs -> full recompute
    assert req.prefill_stats["policy"] == "full_recompute"
    assert len(req.output_tokens) == 3


def test_engine_decode_matches_offline(engine):
    """Greedy continuation from the engine == offline decode loop."""
    cfg, eng0 = engine
    m = eng0.model
    params = eng0.params
    eng = MPICEngine(m, params, EngineConfig(max_seq_len=128, decode_slots=1))
    r = np.random.default_rng(3)
    toks = r.integers(8, 200, 12)
    req = Request(prompt=Prompt([text_segment(toks)], user_id="u"),
                  max_new_tokens=4, policy="full_recompute")
    eng.submit(req)
    eng.run()

    # offline: full forward argmax loop
    cur = jnp.asarray(toks[None].astype(np.int32))
    out = []
    for _ in range(4):
        lg = m.forward(params, cur)
        nxt = int(jnp.argmax(lg[0, -1]))
        out.append(nxt)
        cur = jnp.concatenate([cur, jnp.asarray([[nxt]], jnp.int32)], axis=1)
    assert req.output_tokens == out
