"""Fault tolerance: fault-plan determinism, peer circuit breakers, disk
quarantine, loader failure containment, deadlines, replica failover and
the stuck-fleet watchdog — every failure injected through the seeded
``cache/faults.py`` layer, never hand-mocked."""
import time

import jax
import numpy as np
import pytest

from repro.cache import (
    TIER_DISK,
    DictBlockStore,
    FaultPlan,
    FaultRule,
    KVLibrary,
    KVPeerServer,
    ParallelLoader,
    PeerBreaker,
    PeerTransport,
    ReplicaCrash,
)
from repro.cache.backends import NetworkBackend
from repro.configs import get_smoke_config
from repro.core import Prompt, media_segment, text_segment
from repro.data import image_embeds
from repro.serving import (
    ClusterConfig,
    EngineConfig,
    MPICCluster,
    MPICEngine,
    Request,
    State,
    StuckFleetError,
)


def _kv(nbytes=1 << 12):
    n = nbytes // 8
    return (np.zeros((1, n // 16, 2, 8), np.float32),
            np.zeros((1, n // 16, 2, 8), np.float32))


# ---------------------------------------------------------------------------
# FaultPlan units
# ---------------------------------------------------------------------------

def test_fault_plan_determinism():
    """Same (spec, seed, event sequence) → bit-identical firing pattern."""
    spec = "disk.read:io_error:prob=0.4;peer.request:blackhole:start=2"
    runs = []
    for _ in range(2):
        plan = FaultPlan.parse(spec, seed=7)
        fired = [(plan.check("disk.read", "k") is not None,
                  plan.check("peer.request", "p") is not None)
                 for _ in range(50)]
        runs.append(fired)
    assert runs[0] == runs[1]
    assert any(f[0] for f in runs[0]) and not all(f[0] for f in runs[0])


def test_fault_plan_window_and_target():
    plan = FaultPlan([FaultRule("engine.step", "crash", target="replica1",
                                start=2, stop=3)])
    assert plan.check("engine.step", "replica0") is None
    assert plan.check("disk.read", "replica1") is None
    hits = [plan.check("engine.step", "replica1") for _ in range(4)]
    assert [h is not None for h in hits] == [False, False, True, False]
    assert plan.stats()[0]["matched"] == 4
    assert plan.stats()[0]["fired"] == 1


def test_fault_plan_parse_errors():
    with pytest.raises(ValueError):
        FaultPlan.parse("justasite")
    with pytest.raises(ValueError):
        FaultPlan.parse("disk.read:io_error:notakv")
    with pytest.raises(ValueError):
        FaultPlan.parse("disk.read:io_error:bogus=1")
    # the serve.py alias: delay= means delay_s=
    plan = FaultPlan.parse("peer.request:latency:delay=0.25")
    assert plan.rules[0].delay_s == 0.25


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------

def test_peer_breaker_state_machine():
    now = [0.0]
    br = PeerBreaker(threshold=3, cooldown_s=10.0, clock=lambda: now[0])
    for _ in range(2):
        assert br.allow()
        br.record_failure()
    assert br.state == PeerBreaker.CLOSED
    br.record_success()                       # any response resets the streak
    assert br.failure_streak == 0
    for _ in range(3):
        br.record_failure()
    assert br.state == PeerBreaker.OPEN
    assert not br.allow() and br.skips == 1   # open: short-circuit
    now[0] = 11.0
    assert br.allow()                         # half-open: exactly one probe
    assert br.state == PeerBreaker.HALF_OPEN
    assert not br.allow()                     # second concurrent probe denied
    br.record_failure()                       # probe failed → reopen
    assert br.state == PeerBreaker.OPEN
    now[0] = 22.0
    assert br.allow()
    br.record_success()                       # probe succeeded → close
    assert br.state == PeerBreaker.CLOSED and br.failure_streak == 0


def test_dead_peer_trips_breaker_and_bounds_cost():
    """A blackholed peer pays its timeout only ``threshold`` times; after
    the breaker opens every miss is a free skip, not a timeout."""
    srv = KVPeerServer(DictBlockStore())
    try:
        t = PeerTransport(srv.address, timeout_s=0.05, retries=0)
        nb = NetworkBackend([t], faults=FaultPlan.parse(
            "peer.request:blackhole"), breaker_cooldown_s=60.0)
        for i in range(3):
            assert nb.get(f"ident{i}") is None
        assert nb.breakers[t.address].state == PeerBreaker.OPEN
        t0 = time.perf_counter()
        for i in range(3, 6):
            assert nb.get(f"ident{i}") is None
        assert time.perf_counter() - t0 < 0.04   # skipped, not timed out
        s = nb.stats()
        assert s["breaker_skips"] == 3
        assert s["breakers"][t.address]["state"] == "open"
        assert s["breakers"][t.address]["opened"] == 1
    finally:
        srv.close()


def test_miss_responses_are_health_not_failure():
    """404 from a live peer is a definitive miss, never breaker food."""
    srv = KVPeerServer(DictBlockStore())
    try:
        t = PeerTransport(srv.address, timeout_s=0.5, retries=0)
        nb = NetworkBackend([t])
        for i in range(5):
            assert nb.get(f"nothing{i}") is None
        br = nb.breakers[t.address]
        assert br.state == PeerBreaker.CLOSED
        assert br.failure_streak == 0 and br.skips == 0
    finally:
        srv.close()


def test_single_transport_failure_recovers():
    """One no-response below the threshold must not open the breaker, and
    the next live response clears the streak."""
    srv = KVPeerServer(DictBlockStore())
    try:
        t = PeerTransport(srv.address, timeout_s=0.05, retries=0)
        nb = NetworkBackend([t], faults=FaultPlan.parse(
            "peer.request:blackhole:stop=1"))
        assert nb.get("a") is None            # faulted: transport failure
        br = nb.breakers[t.address]
        assert br.state == PeerBreaker.CLOSED and br.failure_streak == 1
        assert nb.get("b") is None            # live 404
        assert br.failure_streak == 0 and br.skips == 0
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# disk-tier degradation
# ---------------------------------------------------------------------------

def test_disk_quarantine_after_consecutive_read_failures(tmp_path):
    k, v = _kv(1 << 14)
    per = k.nbytes + v.nbytes
    lib = KVLibrary(hbm_capacity=per, host_capacity=1,
                    spool_dir=str(tmp_path),
                    faults=FaultPlan.parse("disk.read:io_error"))
    for m in "abc":                 # each put spools the previous to disk
        lib.put("u", m, k, v)
    lib.put("u", "d", k, v)
    assert sorted(m for m in "abc"
                  if lib.peek_tier("u", m) == TIER_DISK) == list("abc")
    for m in "abc":                 # 3 consecutive injected IO failures
        assert lib.get("u", m) is None        # device error ⇒ miss
    deg = lib.stats()["degraded"]
    assert deg["disk_quarantined"] is True
    assert deg["disk_failure_streak"] >= 3
    assert lib.stats()["tiers"][TIER_DISK]["quarantined"] is True
    assert lib.get("u", "d") is not None      # memory tier keeps serving
    # spooling is off while quarantined: new pressure never reaches disk
    n_disk = sum(1 for e in lib._entries.values() if e.tier == TIER_DISK)
    lib.put("u", "e", k, v)
    lib.put("u", "f", k, v)
    assert sum(1 for e in lib._entries.values()
               if e.tier == TIER_DISK) == n_disk
    lib.reinstate_disk()                      # operator override
    assert lib.stats()["degraded"]["disk_quarantined"] is False


def test_enospc_counts_but_never_quarantines(tmp_path):
    """A full disk is an operator signal, not a dying device: the demotion
    fails non-fatally (entry stays resident) and the tier stays live."""
    k, v = _kv(1 << 14)
    per = k.nbytes + v.nbytes
    lib = KVLibrary(hbm_capacity=per, host_capacity=1,
                    spool_dir=str(tmp_path),
                    faults=FaultPlan.parse("disk.write:enospc"))
    a = lib.put("u", "a", k, v)
    lib.put("u", "b", k, v)         # pressure → spool "a" → injected ENOSPC
    assert a.k is not None                    # failed demotion: still resident
    deg = lib.stats()["degraded"]
    assert deg["enospc"] >= 1 and deg["spool_failures"] >= 1
    assert deg["disk_quarantined"] is False
    assert deg["disk_failure_streak"] == 0    # ENOSPC never feeds the streak
    assert lib.get("u", "a") is not None


# ---------------------------------------------------------------------------
# loader failure containment (worker exceptions = counted miss)
# ---------------------------------------------------------------------------

def test_loader_worker_error_is_counted_miss_not_exception(tmp_path):
    lib = KVLibrary(spool_dir=str(tmp_path),
                    faults=FaultPlan.parse("loader.fetch:error:target=bad"))
    k, v = _kv()
    lib.put("u", "bad", k, v)
    lib.put("u", "good", k, v)
    loader = ParallelLoader(lib, 2)
    try:
        h = loader.prefetch_handle("u", ["bad", "good"])
        assert h.get("bad") is None           # injected worker exception
        assert h.get("good") is not None
        assert loader.load_failures == 1
        assert h.get("bad") is None           # re-gather: still a calm miss
        h.release()
    finally:
        loader.close()


def test_loader_stall_delays_but_still_delivers(tmp_path):
    lib = KVLibrary(spool_dir=str(tmp_path), faults=FaultPlan.parse(
        "loader.fetch:stall:delay=0.1,stop=1"))
    k, v = _kv()
    lib.put("u", "m", k, v)
    loader = ParallelLoader(lib, 2)
    try:
        t0 = time.perf_counter()
        h = loader.prefetch_handle("u", ["m"])
        assert h.get("m") is not None
        assert time.perf_counter() - t0 >= 0.1
        assert loader.load_failures == 0
        h.release()
    finally:
        loader.close()


# ---------------------------------------------------------------------------
# serving: deadlines, abort contract, crash failover, watchdog
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_smoke_config("llava-1.6-7b")
    from repro.models import build_model
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def _prompt(cfg, seed, media=("A", "B"), user_id="u1"):
    r = np.random.default_rng(seed)
    segs = [text_segment(r.integers(8, 200, 5))]
    for mid in media:
        segs.append(media_segment(mid, image_embeds(mid, 16, cfg.d_model)))
        segs.append(text_segment(r.integers(8, 200, 4)))
    return Prompt(segs, user_id=user_id)


def _upload_all(target, cfg, media=("A", "B"), user_id="u1"):
    for mid in media:
        target.upload(user_id, mid, image_embeds(mid, 16, cfg.d_model))


def _req(cfg, seed, **kw):
    kw.setdefault("max_new_tokens", 3)
    return Request(prompt=_prompt(cfg, seed), policy="mpic",
                   policy_kwargs={"k": 4}, **kw)


def test_engine_crash_injection_raises_replica_crash(model_and_params):
    cfg, model, params = model_and_params
    eng = MPICEngine(model, params,
                     EngineConfig(max_seq_len=128, decode_slots=2),
                     faults=FaultPlan.parse("engine.step:crash"))
    with pytest.raises(ReplicaCrash):
        eng.step()


def test_deadline_reaps_waiting_request(model_and_params):
    cfg, model, params = model_and_params
    eng = MPICEngine(model, params,
                     EngineConfig(max_seq_len=128, decode_slots=2))
    _upload_all(eng, cfg)
    baseline = eng.pool.free_pages
    expired = eng.submit(_req(cfg, 1, deadline_s=1e-6))
    ok = eng.submit(_req(cfg, 2))
    time.sleep(0.01)
    eng.run()
    assert expired.state is State.DEADLINE
    assert expired in eng.expired and "deadline" in expired.error
    assert ok.done and len(ok.output_tokens) == 3
    assert eng.pool.free_pages == baseline    # nothing leaked
    assert eng.report()["expired"] == 1


def test_deadline_reaps_mid_decode(model_and_params):
    """A request that outlives its budget while decoding is released
    (slot + pages freed, partial output kept) and the engine keeps
    serving afterwards."""
    cfg, model, params = model_and_params
    eng = MPICEngine(model, params,
                     EngineConfig(max_seq_len=128, decode_slots=2))
    _upload_all(eng, cfg)
    baseline = eng.pool.free_pages
    doomed = eng.submit(_req(cfg, 3, max_new_tokens=100_000,
                             deadline_s=0.4))
    eng.run()
    assert doomed.state is State.DEADLINE and doomed in eng.expired
    assert eng.pool.free_pages == baseline
    survivor = eng.submit(_req(cfg, 4))
    eng.run()
    assert survivor.done and len(survivor.output_tokens) == 3


def _pin_census(lib):
    return {k: e.meta.pins for k, e in lib._entries.items() if e.meta.pins}


def test_abort_prefill_returns_resources_to_baseline(model_and_params):
    """drain_for_failover mid-chunked-prefill: free pages and pin counts
    return to baseline and the request resets to an idempotent WAITING."""
    cfg, model, params = model_and_params
    eng = MPICEngine(model, params,
                     EngineConfig(max_seq_len=128, decode_slots=2,
                                  prefill_chunk_tokens=8))
    _upload_all(eng, cfg)
    baseline = eng.pool.free_pages
    req = eng.submit(_req(cfg, 5))
    for _ in range(6):
        if eng._prefill_tasks:
            break
        eng.step()
    assert eng._prefill_tasks, "prefill never went mid-flight"
    drained = eng.drain_for_failover()
    assert drained == [req]
    assert req.state is State.WAITING
    assert req.output_tokens == [] and req.slot == -1 and req.replica == -1
    assert eng.pool.free_pages == baseline
    assert _pin_census(eng.static_lib) == {}
    # idempotent resubmit on the same engine completes normally
    eng.submit(req)
    eng.run()
    assert req.done and len(req.output_tokens) == 3


def test_abort_prefill_with_stalled_loader(model_and_params):
    """The abort contract holds even while a loader worker is stalled on
    an injected slow fetch — pins drop once the worker retires."""
    cfg, model, params = model_and_params
    plan = FaultPlan.parse("loader.fetch:stall:delay=0.2,target=A")
    eng = MPICEngine(model, params,
                     EngineConfig(max_seq_len=128, decode_slots=2,
                                  prefill_chunk_tokens=8),
                     faults=plan)
    _upload_all(eng, cfg)
    baseline = eng.pool.free_pages
    req = eng.submit(_req(cfg, 6, deadline_s=0.05))
    time.sleep(0.06)                # budget elapses while the fetch stalls
    eng.run()
    assert req.state is State.DEADLINE and req in eng.expired
    assert eng.pool.free_pages == baseline
    eng.loader.close()              # join workers: stalled fetch retires
    assert _pin_census(eng.static_lib) == {}


def test_stuck_fleet_watchdog(model_and_params):
    cfg, model, params = model_and_params
    cluster = MPICCluster(model, params,
                          EngineConfig(max_seq_len=128, decode_slots=2),
                          ClusterConfig(replicas=2))
    _upload_all(cluster, cfg)
    req = cluster.submit(_req(cfg, 7))
    with pytest.raises(StuckFleetError) as ei:
        cluster.run(max_steps=0)
    assert "replicas" in ei.value.fleet or ei.value.fleet  # snapshot attached
    # report mode: same detection, recorded instead of raised
    assert cluster.run(max_steps=0, on_stuck="report") is not None
    assert cluster.stuck_report is not None
    cluster.run()                             # fleet is fine, just early-cut
    assert req.done
    cluster.close()


def test_replica_crash_failover_token_parity(model_and_params):
    """Crash replica 0 mid-run: its queue fails over, every request still
    completes, and tokens are identical to an uncrashed fleet."""
    cfg, model, params = model_and_params

    def serve(faults):
        cluster = MPICCluster(
            model, params, EngineConfig(max_seq_len=128, decode_slots=2),
            ClusterConfig(replicas=2, router="least_loaded", router_seed=0,
                          faults=faults))
        _upload_all(cluster, cfg)
        reqs = [cluster.submit(_req(cfg, 30 + i)) for i in range(6)]
        cluster.run()
        rep = cluster.report()
        cluster.close()
        return reqs, rep

    healthy, _ = serve(None)
    crashed, rep = serve(FaultPlan.parse(
        "engine.step:crash:target=replica0,start=2,stop=3"))
    assert all(r.done for r in crashed)
    assert 0 in rep["quarantined"] and rep["requeued"] > 0
    assert [r.output_tokens for r in crashed] == \
        [r.output_tokens for r in healthy]


def test_all_replicas_down_raises(model_and_params):
    cfg, model, params = model_and_params
    cluster = MPICCluster(
        model, params, EngineConfig(max_seq_len=128, decode_slots=2),
        ClusterConfig(replicas=2, faults=FaultPlan.parse(
            "engine.step:crash")))     # every step of every replica crashes
    _upload_all(cluster, cfg)
    cluster.submit(_req(cfg, 50))
    with pytest.raises(StuckFleetError):
        cluster.run()
    cluster.close()
